"""Streaming serving demo: concurrent requests through the request-handle
front-end (spec -> handle -> events) vs the same requests served one-by-one,
with token-parity verification, a live mid-flight cancellation, and both
SLO-shedding layers — admission rejection and the QosAutopilot's mid-flight
"slo_shed" cancellation (serving/cluster.py; see examples/serve_cluster.py
for the multi-replica layer above this).

The serving API in three moves:

  1. Describe a request:   GenerationRequest(prompt, SamplingParams(...),
                           ttft_slo=..., tbt_slo=..., priority=...)
  2. Submit, get a handle: h = frontend.submit(spec)  — an iterator that
                           streams tokens as the engine emits them, with
                           .status / .result() / .cancel()
  3. Drive cooperatively:  iterating a handle (or frontend.poll()) runs the
                           engine step loop; no threads anywhere.

Cancellation is synchronous: h.cancel() frees the request's KV slot,
drops its expert-residency contributions from the shared ledger, closes
its TBT entry, and the handle is terminal before the call returns —
surviving requests' tokens are bit-unaffected.

  PYTHONPATH=src python examples/serve_concurrent.py --requests 4 --max-new 5
  PYTHONPATH=src python examples/serve_concurrent.py --smoke   # CI
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core.qos import AdmissionController, LatencyModel, percentile_report
from repro.data.pipeline import PromptWorkload, squad_like
from repro.models.model import build
from repro.serving.api import GenerationRequest, SamplingParams
from repro.serving.batching import (BatchedServingEngine, RequestQueue,
                                    parse_prefill_budget)
from repro.serving.cluster import QosAutopilot
from repro.serving.engine import MoEServingEngine
from repro.serving.frontend import ServingFrontend


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=5)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--policy", default="duo+")
    ap.add_argument("--prefill-budget", default=None,
                    help="prompt tokens of chunked prefill per engine step "
                         "(stall-free interleaving), or 'auto' to derive "
                         "the chunk from the live LatencyModel via "
                         "--tbt-slo; default monolithic")
    ap.add_argument("--tbt-slo", type=float, default=None,
                    help="target inter-token gap (s) for auto budget")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast run for CI: small workload, chunked "
                         "prefill, asserts parity + cancellation safety")
    args = ap.parse_args()

    if args.smoke:
        args.requests, args.max_new = 3, 3
        args.prefill_budget = args.prefill_budget or "2"

    cfg = reduced(get_config(args.arch))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    wl = PromptWorkload(squad_like(cfg.vocab), seed=5)
    prompts = [p[:16] for p, _ in wl.prompts(args.requests)]

    # sequential baseline (paper-scope engine, one request at a time)
    seq = MoEServingEngine(cfg, params, policy=args.policy, temperature=0.0)
    t0 = time.perf_counter()
    seq_results = [seq.serve(p, max_new=args.max_new) for p in prompts]
    seq_wall = time.perf_counter() - t0

    # [streaming] all requests in flight through the request-handle
    # front-end: submit typed specs, stream each handle round-robin so the
    # tokens print in the interleaved order the engine produces them
    eng = BatchedServingEngine(cfg, params, policy=args.policy,
                               max_batch=args.max_batch, max_seq=64,
                               prefill_budget=parse_prefill_budget(
                                   args.prefill_budget),
                               tbt_slo=args.tbt_slo,
                               temperature=0.0)
    fe = ServingFrontend(eng)
    t0 = time.perf_counter()
    handles = [fe.submit(GenerationRequest(
        prompt=p, params=SamplingParams(max_new_tokens=args.max_new),
        priority=i % 2))          # alternate priorities, just to show them
        for i, p in enumerate(prompts)]
    streams = [[] for _ in handles]
    iters = [iter(h) for h in handles]
    live = list(range(len(handles)))
    while live:
        for i in list(live):
            try:
                streams[i].append(next(iters[i]))
            except StopIteration:
                live.remove(i)
    batch_wall = time.perf_counter() - t0

    print(f"{args.requests} requests, max_new={args.max_new}, "
          f"policy={args.policy}")
    ok = True
    for i, (h, s) in enumerate(zip(handles, seq_results)):
        same = bool(np.array_equal(np.asarray(streams[i]), s.tokens))
        ok &= same
        print(f"  req{i}: streamed={streams[i]} status={h.status} "
              f"reason={h.finish_reason} match_sequential={same}")
    ttfts = [h.result().ttft_wall for h in handles]
    print(f"sequential wall: {seq_wall:6.2f}s   "
          f"streamed wall: {batch_wall:6.2f}s "
          f"({seq_wall / max(batch_wall, 1e-9):.2f}x)")
    print(f"streamed TTFT: {percentile_report(ttfts)}  "
          f"mean decode batch: {np.mean(eng.decode_batch_hist):.2f}")
    assert ok, "streamed tokens diverged from sequential"

    # [cancellation] a fresh batch; one request is cancelled after its
    # second token — its KV slot and expert budget free immediately, the
    # survivor's tokens stay bit-identical to its sequential run. Needs
    # two prompts and enough decode steps for a mid-flight cancel.
    if args.requests < 2 or args.max_new < 2:
        print("cancellation demo skipped (needs --requests >= 2 and "
              "--max-new >= 2)")
    else:
        eng2 = BatchedServingEngine(cfg, params, policy=args.policy,
                                    max_batch=2, max_seq=64,
                                    prefill_budget=parse_prefill_budget(
                                        args.prefill_budget),
                                    tbt_slo=args.tbt_slo, temperature=0.0)
        fe2 = ServingFrontend(eng2)
        survivor = fe2.submit(GenerationRequest(
            prompt=prompts[0],
            params=SamplingParams(max_new_tokens=args.max_new)))
        victim = fe2.submit(GenerationRequest(
            prompt=prompts[1],
            params=SamplingParams(max_new_tokens=args.max_new)))
        while len(victim.tokens) < 2 and not victim.done:
            fe2.poll()
        t_req = time.perf_counter()
        assert victim.cancel()
        t_cancel = victim.events[-1].t - t_req
        fe2.drain()
        surv_ok = bool(np.array_equal(survivor.result().tokens,
                                      seq_results[0].tokens))
        print(f"cancellation demo: victim cancelled after "
              f"{len(victim.tokens)} tokens in {t_cancel * 1e3:.2f}ms "
              f"(slot freed: {victim.req.slot in eng2._free}); "
              f"survivor bit-exact: {surv_ok}")
        assert surv_ok, "cancellation perturbed the surviving request"
        assert victim.finish_reason == "cancelled"

    # [SLO shedding] two layers close the QoS loop:
    #  * admission: a pessimistic cost model + tight deadline -> the queue
    #    rejects the request before it ever takes a KV slot;
    #  * QosAutopilot (serving/cluster.py): requests that WERE admitted but
    #    whose deadline becomes unmeetable mid-flight are shed
    #    automatically with reason="slo_shed" — no hand-rolled
    #    deadline-watching + h.cancel() loop in caller code anymore.
    queue = RequestQueue(AdmissionController(
        LatencyModel(prefill_per_token=10.0), default_ttft_slo=1.0))
    shed = BatchedServingEngine(cfg, params, policy=args.policy,
                                max_batch=2, max_seq=64, queue=queue,
                                temperature=0.0)
    fe3 = ServingFrontend(shed)
    doomed = fe3.submit(GenerationRequest(
        prompt=prompts[0], params=SamplingParams(max_new_tokens=2)))
    fe3.poll()
    print(f"SLO demo: {len(queue.rejected)} request(s) shed at admission "
          f"(predicted TTFT over a 1s deadline); handle status: "
          f"{doomed.status}")

    fe4 = ServingFrontend(BatchedServingEngine(
        cfg, params, policy=args.policy, max_batch=2, max_seq=64,
        temperature=0.0))
    autopilot = QosAutopilot(fe4)
    laggard = fe4.submit(GenerationRequest(
        prompt=prompts[0], params=SamplingParams(max_new_tokens=16),
        tbt_slo=0.3))
    while len(laggard.tokens) < 2 and not laggard.done:
        fe4.poll()
    # scan with a clock far past the next token's 300ms deadline — in a
    # real deployment the poll loop's own wall clock does this
    fe4.poll(time.perf_counter() + 100.0)
    print(f"autopilot demo: laggard shed mid-decode after "
          f"{len(laggard.tokens)} tokens (reason={laggard.finish_reason}, "
          f"shed counts={autopilot.by_reason}, engine n_slo_shed="
          f"{fe4.engine.n_slo_shed})")

    if args.smoke:
        assert doomed.finish_reason == "rejected"
        assert laggard.finish_reason == "slo_shed"
        assert autopilot.n_shed == 1
        assert laggard.req.slot in fe4.engine._free
        print("serve_concurrent smoke OK")


if __name__ == "__main__":
    main()
