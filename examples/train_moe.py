"""Training driver example: train a small Qwen2-MoE-family model with the
full substrate (sort+capacity dispatch, load-balance aux, AdamW, microbatch
accumulation, checkpointing). At cluster scale the same step function is what
launch/train.py shards over the production mesh.

  PYTHONPATH=src python examples/train_moe.py --steps 100
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.data.pipeline import SyntheticLM
from repro.models.model import build
from repro.training import checkpoint
from repro.training.optimizer import AdamW, cosine_schedule
from repro.training.train_loop import make_eval_step, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-moe-a2.7b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt", default="/tmp/duoserve_train.npz")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params / 1e6:.1f}M params "
          f"(E={cfg.n_experts} top-{cfg.top_k} + {cfg.n_shared_experts} shared)")

    opt = AdamW(lr=cosine_schedule(2e-3, warmup=10, total=args.steps))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(bundle, opt,
                                   microbatches=args.microbatches))
    data = SyntheticLM(cfg.vocab, seed=0)
    it = data.batches(args.batch, args.seq)

    t0 = time.time()
    for i in range(args.steps):
        batch = {"tokens": jnp.asarray(next(it))}
        params, opt_state, m = step(params, opt_state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"aux {float(m['aux']):.4f}  "
                  f"|g| {float(m['grad_norm']):.2f}  "
                  f"{(i + 1) / (time.time() - t0):.2f} it/s")
    checkpoint.save(args.ckpt, params, extra={"steps": args.steps})
    print("checkpoint ->", args.ckpt)


if __name__ == "__main__":
    main()
