"""End-to-end serving driver: batched requests through the DuoServe runtime
with every policy, QoS summary table (the paper's Fig. 5/6 shape at demo
scale). This is the serving counterpart of a training driver — the paper is
an inference-serving system.

  PYTHONPATH=src python examples/serve_e2e.py --requests 6 --max-new 6
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core.predictor import train_predictor
from repro.core.qos import summarize
from repro.core.scheduler import make_scheduler
from repro.core.simulator import HW, ModelCosts, simulate_request
from repro.core.state import StateConstructor
from repro.data.pipeline import PromptWorkload, squad_like
from repro.models.model import build
from repro.serving.engine import MoEServingEngine, collect_traces


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=6)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    wl = PromptWorkload(squad_like(cfg.vocab), seed=5)

    # preprocess
    tracer, _ = collect_traces(
        cfg, params, [p[:32] for p, _ in wl.prompts(8)], max_new=6)
    stats = tracer.stats()
    sc = StateConstructor(stats)
    X, Y = sc.build_dataset(tracer.as_array())
    predictor, _ = train_predictor(jax.random.PRNGKey(1), X, Y, cfg.top_k,
                                   width_scale=0.1, epochs=5, batch=32)

    reqs = [p[:32] for p, _ in wl.prompts(args.requests)]
    print(f"{'policy':8s} {'wall_ttft':>9s} {'wall_e2e':>9s} "
          f"{'sim_p50':>8s} {'sim_p95':>8s} {'hit':>5s}  tokens(first req)")
    full = get_config("mixtral_8x7b")
    costs = ModelCosts(full, quant_bytes=0.5)
    ref_tokens = None
    for pol in ("odf", "lfp", "mif", "duo", "duo+"):
        eng = MoEServingEngine(cfg, params, policy=pol, stats=stats,
                               predictor=predictor)
        results = [eng.serve(p, max_new=args.max_new) for p in reqs]
        if ref_tokens is None:
            ref_tokens = results[0].tokens
        else:
            assert (results[0].tokens == ref_tokens).all(), \
                "policies must not change outputs"
        sims = []
        for r in results:
            fstats = stats.tiled(full.n_layers)
            sched = make_scheduler(
                pol, full.n_layers, full.n_experts, full.top_k,
                int(costs.expert_bytes), stats=fstats, predictor=predictor,
                state_constructor=StateConstructor(fstats))
            reps = full.n_layers // cfg.n_layers
            pa = (r.prefill_active * reps)[: full.n_layers]
            dt = np.tile(r.decode_trace, (1, reps, 1))[:, : full.n_layers]
            sims.append(simulate_request(sched, costs, HW(), pa, dt,
                                         seq_len=256))
        q = summarize([s.ttft for s in sims], [s.e2e for s in sims],
                      total_tokens=args.requests * args.max_new,
                      hit_rate=float(np.mean([s.hit_rate for s in sims])))
        wt = np.mean([r.ttft_wall for r in results])
        we = np.mean([r.e2e_wall for r in results])
        print(f"{pol:8s} {wt:8.2f}s {we:8.2f}s {q.p50_e2e:7.3f}s "
              f"{q.p95_e2e:7.3f}s {q.hit_rate:5.2f}  "
              f"{results[0].tokens[:6].tolist()}")


if __name__ == "__main__":
    main()
