"""Offline preprocess pipeline (paper Fig. 3, left): dataset slice ->
Experts Tracer -> popularity/affinity matrices -> ExpertMLP training ->
serialized artifacts ready for the inference runtime.

  PYTHONPATH=src python examples/preprocess_pipeline.py --out /tmp/duoserve
"""
import argparse
import os

import jax
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core.predictor import train_predictor
from repro.core.state import StateConstructor
from repro.data.pipeline import PromptWorkload, orca_like, squad_like
from repro.models.model import build
from repro.serving.engine import collect_traces
from repro.training import checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-moe-a2.7b")
    ap.add_argument("--dataset", default="squad", choices=["squad", "orca"])
    ap.add_argument("--out", default="/tmp/duoserve_preprocess")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--epochs", type=int, default=6)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cfg = reduced(get_config(args.arch))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    checkpoint.save(os.path.join(args.out, "model.npz"), params,
                    extra={"arch": cfg.name})

    spec = (squad_like if args.dataset == "squad" else orca_like)(cfg.vocab)
    wl = PromptWorkload(spec, seed=3)
    prompts = [p[:40] for p, _ in wl.prompts(args.requests)]

    print(f"[1/3] tracing {len(prompts)} requests on {cfg.name} ...")
    tracer, results = collect_traces(cfg, params, prompts, max_new=8)
    stats = tracer.stats()
    stats.save(os.path.join(args.out, "trace_stats.npz"))
    print(f"  paths={len(tracer.paths)}  "
          f"popularity entropy/layer="
          f"{(-stats.popularity * np.log(stats.popularity + 1e-9)).sum(1).round(2)}")

    print("[2/3] building supervised dataset + training ExpertMLP ...")
    sc = StateConstructor(stats)
    X, Y = sc.build_dataset(tracer.as_array())
    pred, hist = train_predictor(jax.random.PRNGKey(1), X, Y, cfg.top_k,
                                 width_scale=0.25, epochs=args.epochs,
                                 verbose=True)
    checkpoint.save(os.path.join(args.out, "predictor.npz"),
                    {"params": pred.params, "bn": pred.bn_state},
                    extra={"top_k": pred.top_k})

    print("[3/3] artifacts written to", args.out)
    print("  final val top-k acc:", round(hist["val_topk"][-1], 3),
          " at-least-half:", round(hist["val_half"][-1], 3))


if __name__ == "__main__":
    main()
