"""Quickstart: serve one request through DuoServe-MoE end to end.

Builds a reduced Mixtral-class MoE, runs the offline preprocess (trace ->
popularity/affinity -> ExpertMLP), then serves a prompt with the dual-phase
scheduler and prints the QoS picture vs the ODF baseline.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core.predictor import train_predictor
from repro.core.simulator import HW, ModelCosts, simulate_request
from repro.core.scheduler import make_scheduler
from repro.core.state import StateConstructor
from repro.data.pipeline import PromptWorkload, squad_like
from repro.models.model import build
from repro.serving.engine import MoEServingEngine, collect_traces


def main():
    cfg = reduced(get_config("mixtral_8x7b"))
    print(f"model: {cfg.name}  L={cfg.n_layers} E={cfg.n_experts} "
          f"top-k={cfg.top_k}")
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))

    wl = PromptWorkload(squad_like(cfg.vocab), seed=1)
    prompts = [p[:32] for p, _ in wl.prompts(10)]

    print("\n[offline preprocess] tracing expert activations ...")
    tracer, _ = collect_traces(cfg, params, prompts[:8], max_new=6)
    stats = tracer.stats()
    print(f"  {len(tracer.paths)} activation paths; popularity "
          f"{stats.popularity.shape}, affinity {stats.affinity.shape}")

    print("[offline preprocess] training ExpertMLP ...")
    sc = StateConstructor(stats)
    X, Y = sc.build_dataset(tracer.as_array())
    predictor, hist = train_predictor(jax.random.PRNGKey(1), X, Y, cfg.top_k,
                                      width_scale=0.1, epochs=5, batch=32)
    print(f"  val top-k acc {hist['val_topk'][-1]:.2f}  "
          f"at-least-half {hist['val_half'][-1]:.2f}")

    print("\n[online] serving with DuoServe dual-phase scheduling ...")
    eng = MoEServingEngine(cfg, params, policy="duo", stats=stats,
                           predictor=predictor)
    r = eng.serve(prompts[9], max_new=8)
    print(f"  generated tokens: {r.tokens.tolist()}")
    print(f"  decode cache hits={r.hits} misses={r.misses}")

    print("\n[replay] two-stream simulator @ Mixtral-8x7B scale (AWQ 4bit):")
    full = get_config("mixtral_8x7b")
    costs = ModelCosts(full, quant_bytes=0.5)
    for pol in ("odf", "duo"):
        fstats = stats.tiled(full.n_layers)
        sched = make_scheduler(pol, full.n_layers, full.n_experts, full.top_k,
                               int(costs.expert_bytes), stats=fstats,
                               predictor=predictor,
                               state_constructor=StateConstructor(fstats))
        # project the reduced trace onto the full depth by tiling layers
        reps = full.n_layers // cfg.n_layers
        pa = (r.prefill_active * reps)[: full.n_layers]
        dt = np.tile(r.decode_trace, (1, reps, 1))[:, : full.n_layers]
        s = simulate_request(sched, costs, HW(), pa, dt, seq_len=256)
        print(f"  {pol:4s} ttft={s.ttft:.3f}s e2e={s.e2e:.3f}s "
              f"peak={s.peak_bytes / 1e9:.2f}GB decode_hit={s.hit_rate:.2f}")


if __name__ == "__main__":
    main()
